"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU): shape &
dtype sweeps, masking modes, gradients."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.serving import quant

KEY = jax.random.key(7)


def rand(key_i, shape, dtype=jnp.float32, scale=1.0):
    x = jax.random.normal(jax.random.fold_in(KEY, key_i), shape,
                          jnp.float32) * scale
    return x.astype(dtype)


TOL = {jnp.float32: dict(rtol=2e-3, atol=2e-3),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}

# acceptance bound for the paged-attention kernel (f32 serving shapes)
PAGED_TOL_F32 = dict(rtol=1e-5, atol=1e-5)

# quantization tolerance tiers (docs/kernels.md "Quantized paged KV"):
# drift of a quantized pool's attention output vs the fp32-pool oracle.
# Kernel-vs-ref parity on the SAME quantized inputs stays at the f32
# bound — both sides dequantize identical codes, so the only error is
# the same online-softmax reassociation fp32 already tolerates.
# Measured drift on N(0,1) pools: int8 ~1e-2 (7.9-bit mantissa at
# per-(token, head) absmax scaling), fp8_e4m3 ~7e-2 (3-bit mantissa).
KV_TIERS = {"fp32": PAGED_TOL_F32,
            "int8": dict(rtol=5e-2, atol=5e-2),
            "fp8_e4m3": dict(rtol=1.5e-1, atol=1.5e-1)}


class TestFlashAttention:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("window", [None, 64])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_masks_and_dtypes(self, causal, window, dtype):
        q = rand(1, (2, 4, 256, 128), dtype)
        k = rand(2, (2, 2, 256, 128), dtype)
        v = rand(3, (2, 2, 256, 128), dtype)
        out = ops.flash_attention(q, k, v, causal, None, window)
        exp = ref.flash_attention(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(exp, np.float32),
            **TOL[dtype])

    @pytest.mark.parametrize("shape", [
        (1, 1, 128, 64),     # MQA small head
        (2, 8, 384, 128),    # non-pow2 seq (block remainder)
        (1, 4, 256, 96),     # pad path (d % 128 != 0, MLA-like)
        (1, 4, 512, 256),    # gemma head_dim 256
    ])
    def test_shape_sweep(self, shape):
        b, h, s, d = shape
        hkv = max(1, h // 2)
        q = rand(4, (b, h, s, d))
        k = rand(5, (b, hkv, s, d))
        v = rand(6, (b, hkv, s, d))
        out = ops.flash_attention(q, k, v, True, None, None)
        exp = ref.flash_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=2e-3, atol=2e-3)

    def test_custom_scale(self):
        q = rand(7, (1, 2, 128, 128))
        k = rand(8, (1, 2, 128, 128))
        v = rand(9, (1, 2, 128, 128))
        out = ops.flash_attention(q, k, v, True, 0.05, None)
        exp = ref.flash_attention(q, k, v, causal=True, scale=0.05)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=2e-3, atol=2e-3)

    def test_gradients_match_ref(self):
        q = rand(10, (1, 4, 128, 64))
        k = rand(11, (1, 2, 128, 64))
        v = rand(12, (1, 2, 128, 64))
        g1 = jax.grad(lambda a, b, c: ops.flash_attention(
            a, b, c, True, None, None).sum(), argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(lambda a, b, c: ref.flash_attention(
            a, b, c, causal=True).sum(), argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-3)

    def test_jit_compatible(self):
        q = rand(13, (1, 2, 128, 128))
        k = rand(14, (1, 2, 128, 128))
        v = rand(15, (1, 2, 128, 128))
        f = jax.jit(lambda a, b, c: ops.flash_attention(a, b, c, True,
                                                        None, None))
        np.testing.assert_allclose(
            np.asarray(f(q, k, v)),
            np.asarray(ref.flash_attention(q, k, v, causal=True)),
            rtol=2e-3, atol=2e-3)


class TestDecodeAttention:
    @pytest.mark.parametrize("window", [None, 128])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_ragged_lengths(self, window, dtype):
        B, Hq, Hkv, Smax, D = 3, 8, 2, 512, 128
        q = rand(20, (B, Hq, 1, D), dtype)
        kc = rand(21, (B, Hkv, Smax, D), dtype)
        vc = rand(22, (B, Hkv, Smax, D), dtype)
        lens = jnp.array([500, 512, 130], jnp.int32)
        out = ops.decode_attention(q, kc, vc, lens, window=window)
        exp = ref.decode_attention(q, kc, vc, lens, window=window)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(exp, np.float32),
                                   **TOL[dtype])

    def test_scalar_len_broadcast(self):
        q = rand(23, (2, 4, 1, 64))
        kc = rand(24, (2, 4, 256, 64))
        vc = rand(25, (2, 4, 256, 64))
        out = ops.decode_attention(q, kc, vc, 77)
        exp = ref.decode_attention(q, kc, vc, 77)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=2e-3, atol=2e-3)

    def test_mqa_group(self):
        q = rand(26, (1, 8, 1, 128))
        kc = rand(27, (1, 1, 256, 128))
        vc = rand(28, (1, 1, 256, 128))
        out = ops.decode_attention(q, kc, vc, jnp.array([200]))
        exp = ref.decode_attention(q, kc, vc, jnp.array([200]))
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=2e-3, atol=2e-3)


class TestPagedAttention:
    """Block-table-prefetching kernel vs the gather-then-attend oracle."""

    def _tables(self, s, p, n_pages, key_i):
        """Random DISTINCT physical page ids per slot (p pages each)."""
        perm = jax.random.permutation(jax.random.fold_in(KEY, key_i),
                                      n_pages)[: s * p]
        return perm.reshape(s, p).astype(jnp.int32)

    @pytest.mark.parametrize("window", [None, 6])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_mixed_prefill_decode_batch(self, window, dtype):
        """A flat batch mixing a prefill chunk (slot 0), a fresh prefill
        start (slot 1) and decode tokens (slot 2) + padding."""
        n_pages, ps, hkv, d, hq = 24, 4, 2, 32, 4
        kp = rand(70, (n_pages, ps, hkv, d), dtype)
        vp = rand(71, (n_pages, ps, hkv, d), dtype)
        q = rand(72, (7, hq, d), dtype)
        tables = self._tables(3, 4, n_pages, 73)
        seg = jnp.asarray([0, 0, 1, 2, 2, 2, -1], jnp.int32)
        pos = jnp.asarray([3, 4, 0, 10, 14, 15, 0], jnp.int32)
        out = ops.paged_attention(q, kp, vp, tables, seg, pos,
                                  window=window)
        exp = ref.paged_attention(q, kp, vp, tables, seg, pos,
                                  window=window)
        tol = PAGED_TOL_F32 if dtype == jnp.float32 else TOL[dtype]
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(exp, np.float32), **tol)

    def test_ragged_page_counts(self):
        """Slots with very different live-page counts: table rows are
        0-padded past each sequence's last page and masking must keep
        the padding pages out of the softmax."""
        n_pages, ps, hkv, d, hq = 40, 8, 2, 16, 8
        kp = rand(74, (n_pages, ps, hkv, d))
        vp = rand(75, (n_pages, ps, hkv, d))
        q = rand(76, (4, hq, d))
        tables = np.zeros((4, 4), np.int32)
        tables[0, :1] = [5]                   # 3 tokens: 1 page
        tables[1, :4] = [7, 9, 11, 13]        # 30 tokens: 4 pages
        tables[2, :2] = [2, 3]                # 12 tokens: 2 pages
        tables[3, :1] = [17]
        seg = jnp.asarray([0, 1, 2, 3], jnp.int32)
        pos = jnp.asarray([2, 29, 11, 0], jnp.int32)
        tables = jnp.asarray(tables)
        out = ops.paged_attention(q, kp, vp, tables, seg, pos)
        exp = ref.paged_attention(q, kp, vp, tables, seg, pos)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=1e-5, atol=1e-5)

    def test_shared_prefix_pages(self):
        """Two slots whose tables reference the SAME physical prefix
        pages (prefix-cache dedup) must each attend the shared content
        plus their own divergent tail."""
        n_pages, ps, hkv, d, hq = 16, 4, 2, 16, 4
        kp = rand(77, (n_pages, ps, hkv, d))
        vp = rand(78, (n_pages, ps, hkv, d))
        q = rand(79, (2, hq, d))
        tables = jnp.asarray([[3, 5, 8, 0],    # shared pages 3, 5
                              [3, 5, 9, 0]], jnp.int32)
        seg = jnp.asarray([0, 1], jnp.int32)
        pos = jnp.asarray([10, 11], jnp.int32)
        out = ops.paged_attention(q, kp, vp, tables, seg, pos)
        exp = ref.paged_attention(q, kp, vp, tables, seg, pos)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=1e-5, atol=1e-5)
        # divergent tails -> divergent outputs even at equal positions
        q_same = jnp.stack([q[0], q[0]])
        pos_same = jnp.asarray([11, 11], jnp.int32)
        o = ops.paged_attention(q_same, kp, vp, tables, seg, pos_same)
        assert not np.allclose(np.asarray(o[0]), np.asarray(o[1]))

    def test_matches_gathered_mixed_attention(self):
        """paged_attention over pages == mixed_attention over the
        explicitly gathered per-slot cache (the path it replaced)."""
        n_pages, ps, hkv, d, hq, s, p = 20, 4, 2, 16, 4, 3, 3
        kp = rand(80, (n_pages, ps, hkv, d))
        vp = rand(81, (n_pages, ps, hkv, d))
        q = rand(82, (5, hq, d))
        tables = self._tables(s, p, n_pages, 83)
        seg = jnp.asarray([0, 1, 1, 2, -1], jnp.int32)
        pos = jnp.asarray([4, 7, 8, 11, 0], jnp.int32)
        gidx = (tables[:, :, None] * ps
                + jnp.arange(ps)[None, None, :]).reshape(s, p * ps)
        kc = jnp.take(kp.reshape(n_pages * ps, hkv, d), gidx,
                      axis=0).transpose(0, 2, 1, 3)
        vc = jnp.take(vp.reshape(n_pages * ps, hkv, d), gidx,
                      axis=0).transpose(0, 2, 1, 3)
        out = ops.paged_attention(q, kp, vp, tables, seg, pos)
        exp = ops.mixed_attention(q, kc, vc, seg, pos)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=1e-5, atol=1e-5)

    # ---- quantized KV tier (int8 / fp8_e4m3 codes + per-token scales)

    def _quant_pool(self, kp, vp, kv_dtype):
        """Quantize an fp32 pool into (codes, scales); fp32 passthrough."""
        if kv_dtype == "fp32":
            return kp, vp, None, None
        kc, ksc = quant.quantize(kp, kv_dtype)
        vc, vsc = quant.quantize(vp, kv_dtype)
        return kc, vc, ksc, vsc

    def _check_tier(self, q, kp, vp, tables, seg, pos, kv_dtype,
                    window=None):
        """Two bounds per tier: kernel-vs-ref parity on the SAME
        quantized inputs at the fp32 tolerance (both sides dequantize
        identical codes), and drift vs the fp32-pool oracle at the
        documented tier bound."""
        kc, vc, ksc, vsc = self._quant_pool(kp, vp, kv_dtype)
        out = ops.paged_attention(q, kc, vc, tables, seg, pos,
                                  window=window, k_scale=ksc,
                                  v_scale=vsc)
        exp = ref.paged_attention(q, kc, vc, tables, seg, pos,
                                  window=window, k_scale=ksc,
                                  v_scale=vsc)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   **PAGED_TOL_F32)
        oracle = ref.paged_attention(q, kp, vp, tables, seg, pos,
                                     window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                                   **KV_TIERS[kv_dtype])
        return out

    @pytest.mark.parametrize("window", [None, 6])
    @pytest.mark.parametrize("kv_dtype", sorted(KV_TIERS))
    def test_quant_mixed_prefill_decode(self, kv_dtype, window):
        """Quantized pools through the mixed prefill/decode batch."""
        n_pages, ps, hkv, d, hq = 24, 4, 2, 32, 4
        kp = rand(70, (n_pages, ps, hkv, d))
        vp = rand(71, (n_pages, ps, hkv, d))
        q = rand(72, (7, hq, d))
        tables = self._tables(3, 4, n_pages, 73)
        seg = jnp.asarray([0, 0, 1, 2, 2, 2, -1], jnp.int32)
        pos = jnp.asarray([3, 4, 0, 10, 14, 15, 0], jnp.int32)
        self._check_tier(q, kp, vp, tables, seg, pos, kv_dtype,
                         window=window)

    @pytest.mark.parametrize("kv_dtype", sorted(KV_TIERS))
    def test_quant_ragged_page_counts(self, kv_dtype):
        """Padding pages past each sequence's end must stay masked even
        though their (zero) scales dequantize them to exact zeros."""
        n_pages, ps, hkv, d, hq = 40, 8, 2, 16, 8
        kp = rand(74, (n_pages, ps, hkv, d))
        vp = rand(75, (n_pages, ps, hkv, d))
        q = rand(76, (4, hq, d))
        tables = np.zeros((4, 4), np.int32)
        tables[0, :1] = [5]
        tables[1, :4] = [7, 9, 11, 13]
        tables[2, :2] = [2, 3]
        tables[3, :1] = [17]
        seg = jnp.asarray([0, 1, 2, 3], jnp.int32)
        pos = jnp.asarray([2, 29, 11, 0], jnp.int32)
        self._check_tier(q, kp, vp, jnp.asarray(tables), seg, pos,
                         kv_dtype)

    @pytest.mark.parametrize("kv_dtype", sorted(KV_TIERS))
    def test_quant_shared_prefix_pages(self, kv_dtype):
        """Shared physical prefix pages share ONE set of codes+scales;
        both referencing slots must dequantize them identically."""
        n_pages, ps, hkv, d, hq = 16, 4, 2, 16, 4
        kp = rand(77, (n_pages, ps, hkv, d))
        vp = rand(78, (n_pages, ps, hkv, d))
        q = rand(79, (2, hq, d))
        tables = jnp.asarray([[3, 5, 8, 0], [3, 5, 9, 0]], jnp.int32)
        seg = jnp.asarray([0, 1], jnp.int32)
        pos = jnp.asarray([10, 11], jnp.int32)
        out = self._check_tier(q, kp, vp, tables, seg, pos, kv_dtype)
        # divergent tails -> divergent outputs even at equal positions
        kc, vc, ksc, vsc = self._quant_pool(kp, vp, kv_dtype)
        q_same = jnp.stack([q[0], q[0]])
        pos_same = jnp.asarray([11, 11], jnp.int32)
        o = ops.paged_attention(q_same, kc, vc, tables, seg, pos_same,
                                k_scale=ksc, v_scale=vsc)
        assert not np.allclose(np.asarray(o[0]), np.asarray(o[1]))
        assert np.isfinite(np.asarray(out)).all()

    @pytest.mark.parametrize("kv_dtype", sorted(KV_TIERS))
    def test_multi_page_tiles_bitwise(self, kv_dtype):
        """pages_per_tile is a pure grid re-packing: every tile size
        must produce BITWISE-identical outputs (the kernel unrolls the
        same per-page online-softmax updates in the same order)."""
        n_pages, ps, hkv, d, hq = 24, 4, 2, 32, 4
        kp = rand(70, (n_pages, ps, hkv, d))
        vp = rand(71, (n_pages, ps, hkv, d))
        q = rand(72, (7, hq, d))
        tables = self._tables(3, 4, n_pages, 73)
        seg = jnp.asarray([0, 0, 1, 2, 2, 2, -1], jnp.int32)
        pos = jnp.asarray([3, 4, 0, 10, 14, 15, 0], jnp.int32)
        kc, vc, ksc, vsc = self._quant_pool(kp, vp, kv_dtype)
        base = ops.paged_attention(q, kc, vc, tables, seg, pos,
                                   k_scale=ksc, v_scale=vsc,
                                   pages_per_tile=1)
        for ppt in (2, 3, 4, 7):          # 7 > p_pages exercises clamp
            tiled = ops.paged_attention(q, kc, vc, tables, seg, pos,
                                        k_scale=ksc, v_scale=vsc,
                                        pages_per_tile=ppt)
            np.testing.assert_array_equal(np.asarray(base),
                                          np.asarray(tiled))

    def test_multi_page_tiles_with_window(self):
        """Tile packing composes with sliding-window masking."""
        n_pages, ps, hkv, d, hq = 24, 4, 2, 32, 4
        kp = rand(74, (n_pages, ps, hkv, d))
        vp = rand(75, (n_pages, ps, hkv, d))
        q = rand(76, (7, hq, d))
        tables = self._tables(3, 4, n_pages, 77)
        seg = jnp.asarray([0, 0, 1, 2, 2, 2, -1], jnp.int32)
        pos = jnp.asarray([3, 4, 0, 10, 14, 15, 0], jnp.int32)
        exp = ref.paged_attention(q, kp, vp, tables, seg, pos, window=6)
        for ppt in (1, 2, 4):
            out = ops.paged_attention(q, kp, vp, tables, seg, pos,
                                      window=6, pages_per_tile=ppt)
            np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                       **PAGED_TOL_F32)

    def test_default_pages_per_tile_heuristic(self):
        """The auto heuristic packs ~BLOCK_K tokens per tile, clamped
        to the table width and a cap of 8 pages."""
        assert ops.default_pages_per_tile(4, 4) == 4
        assert ops.default_pages_per_tile(8, 64) == 8
        assert ops.default_pages_per_tile(256, 16) == 1
        assert ops.default_pages_per_tile(64, 2) == 2


class TestRWKV6:
    @pytest.mark.parametrize("shape", [(2, 3, 128, 64), (1, 2, 96, 32),
                                       (1, 1, 64, 128)])
    def test_shapes(self, shape):
        b, h, s, d = shape
        r = rand(30, shape, scale=0.5)
        k = rand(31, shape, scale=0.5)
        v = rand(32, shape, scale=0.5)
        w = jax.nn.sigmoid(rand(33, shape)) * 0.5 + 0.45
        u = rand(34, (h, d), scale=0.1)
        out, st = ops.rwkv6_scan(r, k, v, w, u)
        eo, es = ref.rwkv6_scan(r, k, v, w, u)
        np.testing.assert_allclose(np.asarray(out), np.asarray(eo),
                                   rtol=5e-3, atol=5e-3)
        np.testing.assert_allclose(np.asarray(st), np.asarray(es),
                                   rtol=5e-3, atol=5e-3)

    def test_bf16(self):
        shape = (1, 2, 128, 64)
        r = rand(35, shape, jnp.bfloat16, 0.5)
        k = rand(36, shape, jnp.bfloat16, 0.5)
        v = rand(37, shape, jnp.bfloat16, 0.5)
        w = (jax.nn.sigmoid(rand(38, shape)) * 0.5 + 0.45).astype(
            jnp.bfloat16)
        u = rand(39, (2, 64), scale=0.1)
        out, _ = ops.rwkv6_scan(r, k, v, w, u)
        eo, _ = ref.rwkv6_scan(r, k, v, w, u)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(eo, np.float32),
                                   rtol=5e-2, atol=5e-2)

    def test_grads(self):
        shape = (1, 2, 64, 32)
        r = rand(40, shape, scale=0.5)
        k = rand(41, shape, scale=0.5)
        v = rand(42, shape, scale=0.5)
        w = jax.nn.sigmoid(rand(43, shape)) * 0.5 + 0.45
        u = rand(44, (2, 32), scale=0.1)
        g1 = jax.grad(lambda a: ops.rwkv6_scan(a, k, v, w, u)[0].sum())(r)
        g2 = jax.grad(lambda a: ref.rwkv6_scan(a, k, v, w, u)[0].sum())(r)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=5e-3, atol=5e-3)


class TestMambaScan:
    @pytest.mark.parametrize("shape", [(2, 96, 256, 16), (1, 64, 512, 16),
                                       (1, 128, 640, 8)])
    def test_shapes(self, shape):
        b, s, di, n = shape
        x = rand(50, (b, s, di), scale=0.5)
        dt = jax.nn.softplus(rand(51, (b, s, di))) * 0.1
        B = rand(52, (b, s, n), scale=0.5)
        C = rand(53, (b, s, n), scale=0.5)
        A = -jnp.exp(rand(54, (di, n)))
        D = jnp.ones((di,))
        out = ops.mamba_scan(x, dt, B, C, A, D)
        exp = ref.mamba_scan(x, dt, B, C, A, D)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=5e-3, atol=5e-3)

    def test_grads(self):
        b, s, di, n = 1, 48, 128, 16
        x = rand(55, (b, s, di), scale=0.5)
        dt = jax.nn.softplus(rand(56, (b, s, di))) * 0.1
        B = rand(57, (b, s, n), scale=0.5)
        C = rand(58, (b, s, n), scale=0.5)
        A = -jnp.exp(rand(59, (di, n)))
        D = jnp.ones((di,))
        g1 = jax.grad(lambda a: ops.mamba_scan(a, dt, B, C, A, D).sum())(x)
        g2 = jax.grad(lambda a: ref.mamba_scan(a, dt, B, C, A, D).sum())(x)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=5e-3, atol=5e-3)

    def test_state_continuity_vs_chunking(self):
        """Chunked kernel must be exact across chunk boundaries."""
        b, s, di, n = 1, 130, 128, 16   # s straddles chunk=64 boundaries
        x = rand(60, (b, s, di), scale=0.5)
        dt = jax.nn.softplus(rand(61, (b, s, di))) * 0.1
        B = rand(62, (b, s, n), scale=0.5)
        C = rand(63, (b, s, n), scale=0.5)
        A = -jnp.exp(rand(64, (di, n)))
        D = jnp.ones((di,))
        out = ops.mamba_scan(x, dt, B, C, A, D)
        exp = ref.mamba_scan(x, dt, B, C, A, D)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=5e-3, atol=5e-3)
