"""Per-architecture smoke tests: reduced same-family config, one forward
+ one train grad step on CPU, shape and NaN checks; decode step for
decodable archs.  (Full configs are exercised via the dry-run only.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ARCHS, SkipSpec, get_config, get_shapes,
                           get_smoke_config, input_specs)
from repro.models.lm import (decode_step, forward, init_cache, init_params,
                             lm_loss)

BATCH, SEQ = 2, 12


def _batch_for(cfg):
    tok = jax.random.randint(jax.random.key(1), (BATCH, SEQ), 0,
                             cfg.vocab_size)
    if cfg.input_mode == "embeddings":
        emb = jax.random.normal(jax.random.key(2),
                                (BATCH, SEQ, cfg.d_model))
        n_out = cfg.n_classes if not cfg.lm_head else cfg.vocab_size
        return {"embeds": emb,
                "labels": jax.random.randint(jax.random.key(3),
                                             (BATCH, SEQ), 0, n_out)}
    return {"tokens": tok, "labels": tok}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.key(0))
    batch = _batch_for(cfg)
    logits, aux = forward(cfg, params, tokens=batch.get("tokens"),
                          embeds=batch.get("embeds"))
    n_out = cfg.n_classes if not cfg.lm_head else cfg.vocab_size
    assert logits.shape == (BATCH, SEQ, n_out)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_grads(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.key(0))
    batch = _batch_for(cfg)
    if not cfg.lm_head:
        # encoder: frame-classification CE over cls_head logits
        def loss_fn(p):
            logits, aux = forward(cfg, p, embeds=batch["embeds"])
            lp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            return -jnp.take_along_axis(
                lp, batch["labels"][..., None], axis=-1).mean() + aux
    else:
        def loss_fn(p):
            return lm_loss(cfg, p, batch)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(not bool(jnp.isnan(g).any()) for g in leaves)
    # at least the embedding/backbone receives signal
    total = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in leaves)
    assert total > 0.0


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if not isinstance(
                                      get_shapes(a)["decode_32k"],
                                      SkipSpec)])
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.key(0))
    cache = init_cache(cfg, BATCH, 16, jnp.float32)
    tok = jax.random.randint(jax.random.key(4), (BATCH, 1), 0,
                             cfg.vocab_size)
    logits, new_cache = decode_step(cfg, params, cache, tok, jnp.int32(0))
    assert logits.shape == (BATCH, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    # cache structurally preserved
    a = jax.tree_util.tree_leaves(cache)
    b = jax.tree_util.tree_leaves(new_cache)
    assert len(a) == len(b)
    assert all(x.shape == y.shape for x, y in zip(a, b))


def test_full_configs_match_published_param_counts():
    """The exact configs must hit the published totals (±2%)."""
    import numpy as _np
    from repro.models.lm import abstract_params
    expected = {
        "arctic-480b": 480e9, "jamba-1.5-large-398b": 398e9,
        "yi-34b": 34.4e9, "gemma-2b": 2.5e9, "minicpm3-4b": 4.1e9,
        "llava-next-mistral-7b": 7.24e9, "rwkv6-1.6b": 1.6e9,
        # qwen: 14.3B real + 4 dead expert slots padded for EP
        # divisibility (60→64; §Perf iteration 3c) = 15.15B allocated
        "qwen2-moe-a2.7b": 15.15e9, "gemma3-1b": 1.0e9,
        "hubert-xlarge": 0.96e9,
    }
    for arch, target in expected.items():
        cfg = get_config(arch)
        ap = abstract_params(cfg)
        n = sum(int(_np.prod(l.shape))
                for l in jax.tree_util.tree_leaves(ap))
        assert abs(n - target) / target < 0.05, (arch, n, target)


def test_cell_grid_is_complete():
    cells = [(a, s) for a in ARCHS for s in get_shapes(a)]
    assert len(cells) == 40
    skips = [(a, s) for a in ARCHS
             for s, spec in get_shapes(a).items()
             if isinstance(spec, SkipSpec)]
    assert len(skips) == 8
    # every skip carries a documented reason
    for a, s in skips:
        assert get_shapes(a)[s].reason


def test_input_specs_are_abstract():
    for arch in ARCHS:
        cfg = get_config(arch)
        for name, spec in get_shapes(arch).items():
            if isinstance(spec, SkipSpec):
                continue
            specs = input_specs(cfg, spec)
            for v in specs.values():
                assert isinstance(v, jax.ShapeDtypeStruct)
